"""Request routing over N replicas, with prefix-affinity as the headline.

The shared KV page table dedups prompt prefixes *within one host* — sharing
only materializes if requests carrying the same template land on the same
replica while its pages are resident. Prefix-affinity routing is therefore
the fleet-level counterpart of the paper's multi-ASID TLB sharing: it steers
same-code (same-template) requests to the host already holding those
translations, so the per-host dedup the paper measures actually happens at
fleet scale. Round-robin and least-loaded are the controls.

``simulated_throughput`` scores a fleet run with a simple cost model in
token-equivalents: prefill work not recovered by sharing, plus decode work
inflated by far-tier latency (hw.TPU_TIERED's relative latencies) — the same
three levers as core/tiering's roofline, in request-serving units.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core.hw import TPU_TIERED
from repro.data.requests import Request, RequestGenerator
from repro.fleet.admission import AdmissionController
from repro.fleet.replica import Replica

FAR_LATENCY_REL = TPU_TIERED[1].latency_rel  # host-DRAM far tier vs HBM


class RoundRobinPolicy:
    name = "round-robin"

    def __init__(self):
        self._next = 0

    def choose(self, req: Request, replicas: List[Replica]) -> int:
        i = self._next % len(replicas)
        self._next += 1
        return i


class LeastLoadedPolicy:
    name = "least-loaded"

    def choose(self, req: Request, replicas: List[Replica]) -> int:
        return int(np.argmin([r.load for r in replicas]))


class PrefixAffinityPolicy:
    """Route shared-template requests to the replica holding the prefix.

    Unique prompts (prefix_id == -1) fall back to least-loaded. A sticky
    mapping overloaded past ``spill_factor``x the mean load spills to the
    least-loaded replica instead (a hot template must not melt one host).
    """

    name = "prefix-affinity"

    def __init__(self, spill_factor: float = 3.0):
        self.spill_factor = spill_factor
        self.home: Dict[int, int] = {}  # prefix_id -> replica index
        self.affinity_hits = 0
        self.spills = 0

    def choose(self, req: Request, replicas: List[Replica]) -> int:
        loads = [r.load for r in replicas]
        least = int(np.argmin(loads))
        if req.prefix_id < 0:
            return least
        i = self.home.get(req.prefix_id)
        if i is None:
            self.home[req.prefix_id] = least
            return least
        mean = max(sum(loads) / len(loads), 1.0)
        if loads[i] > self.spill_factor * mean and loads[i] > loads[least]:
            self.spills += 1
            return least
        self.affinity_hits += 1
        return i


POLICIES = {
    "round-robin": RoundRobinPolicy,
    "least-loaded": LeastLoadedPolicy,
    "prefix-affinity": PrefixAffinityPolicy,
}


class FleetRouter:
    """Dispatch + lockstep stepping of the replica set.

    ``admission`` (optional) gates every submit; ``on_step`` hooks (e.g. the
    AutoTierer) run after each fleet step with the global step index.
    """

    def __init__(
        self,
        replicas: List[Replica],
        policy,
        admission: Optional[AdmissionController] = None,
    ):
        assert replicas
        self.replicas = replicas
        self.policy = policy
        self.admission = admission
        self.on_step: List = []
        self.fleet_steps = 0
        self.routed = 0
        self.shed = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Route one request; returns False if admission shed it."""
        if self.admission is not None and not self.admission.admit(req, self.replicas):
            self.shed += 1
            return False
        self.replicas[self.policy.choose(req, self.replicas)].submit(req)
        self.routed += 1
        return True

    def step(self) -> int:
        decoded = sum(r.step() for r in self.replicas)
        self.fleet_steps += 1
        for hook in self.on_step:
            hook(self.fleet_steps)
        return decoded

    @property
    def drained(self) -> bool:
        return all(r.idle for r in self.replicas)

    def run(
        self,
        gen: RequestGenerator,
        n_requests: int,
        max_steps: int = 10_000,
        submit_per_step: Optional[int] = None,
    ) -> dict:
        """Serve ``n_requests``: all up-front, or ``submit_per_step`` per
        fleet step (open-loop arrivals, what admission control acts on)."""
        pending = [next(gen) for _ in range(n_requests)]
        if submit_per_step is None:
            for req in pending:
                self.submit(req)
            pending = []
        steps = 0
        while (pending or not self.drained) and steps < max_steps:
            for _ in range(min(submit_per_step or 0, len(pending))):
                self.submit(pending.pop(0))
            self.step()
            steps += 1
        return self.fleet_stats()

    # ------------------------------------------------------------------
    def fleet_stats(self) -> dict:
        per = [r.stats() for r in self.replicas]
        agg = {
            k: sum(s[k] for s in per)
            for k in (
                "tokens_decoded",
                "requests_finished",
                "prefill_tokens",
                "prefill_tokens_saved",
            )
        }
        hits = sum(r.engine.placement.stats.near_hits for r in self.replicas)
        tot = hits + sum(r.engine.placement.stats.far_hits for r in self.replicas)
        agg["near_hit_rate"] = hits / max(tot, 1)
        agg["shared_mappings"] = sum(s["pagetable"]["shared_mappings"] for s in per)
        agg["fleet_steps"] = self.fleet_steps
        agg["n_replicas"] = len(self.replicas)
        agg["routed"] = self.routed
        agg["shed"] = self.shed
        agg["policy"] = getattr(self.policy, "name", type(self.policy).__name__)
        agg["simulated_throughput"] = simulated_throughput(agg)
        agg["per_replica"] = per
        return agg


def simulated_throughput(stats: dict) -> float:
    """Useful tokens per modeled unit cost (higher is better).

    cost = unshared prefill work + decode work weighted by the average
    KV-read latency its near/far split implies. Prefix sharing removes
    prefill cost; good placement removes the far-latency multiplier.
    """
    useful = stats["prefill_tokens"] + stats["tokens_decoded"]
    near = stats["near_hit_rate"]
    avg_latency = near + (1.0 - near) * FAR_LATENCY_REL
    cost = (
        stats["prefill_tokens"]
        - stats["prefill_tokens_saved"]
        + stats["tokens_decoded"] * avg_latency
    )
    return useful / max(cost, 1e-9)
