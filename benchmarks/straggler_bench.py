"""Straggler & elasticity study: event-driven vs lockstep fleet stepping.

Part 1 — the straggler tax. The same seeded open-loop traffic is served by
4 replicas where host 3 runs 4x slower, once with the legacy lockstep
barrier (every fleet step costs max(step_cost) — the slow host gates
everyone) and once with the virtual-time event scheduler (each host posts
completions on its own clock). Throughput is decoded tokens per unit of
virtual time over a fixed horizon; the event-driven fleet must win, and
the homogeneous control must tie exactly (the equivalence guarantee).

Part 2 — burst-driven autoscale. An arrival burst overloads a 2-replica
elastic fleet; interval shed rate at the admission door triggers scale-up
(new hosts warm their near tier from the AutoTierer's current fleet plan),
the post-burst quiet period drains and retires hosts, and the stitched
fleet trace — including the retired hosts' windows — must still validate
within the paper's <=5% against live counters.
"""
import dataclasses

from repro.configs.workloads import get_profile
from repro.data.requests import RequestGenerator
from repro.fleet import AdmissionController, SLOModel, build_fleet, fleet_vocab, validate_fleet

from _common import fmt_table

HORIZON = 80  # virtual-time budget per straggler cell
SPEEDS = {"homogeneous": (1, 1, 1, 1), "4x-straggler": (1, 1, 1, 4)}


def _profile():
    return dataclasses.replace(
        get_profile("Web1"), prompt_mean=24, decode_mean=6, prefix_share=0.0, n_prefixes=3
    )


def run_straggler_cell(speeds, lockstep: bool, seed: int = 0):
    fleet = build_fleet(
        4, policy="least-loaded", speeds=speeds, trace_window=16, trace_period=32, seed=seed
    )
    gen = RequestGenerator(_profile(), vocab_size=fleet_vocab(), seed=seed + 1)
    # both modes must see the same horizon AND the same offered load per
    # unit of virtual time: a lockstep iteration under the straggler spans
    # max(speeds) time units, so it gets that many ticks' worth of
    # arrivals — otherwise the comparison confounds the barrier tax with
    # arrival volume
    barrier = int(max(speeds))
    max_steps = HORIZON // barrier if lockstep else HORIZON
    per_step = 2 * barrier if lockstep else 2
    stats = fleet.run(
        gen, n_requests=140, max_steps=max_steps, submit_per_step=per_step, lockstep=lockstep
    )
    tput = stats["tokens_decoded"] / max(stats["virtual_time"], 1e-9)
    return tput, stats


def run_autoscale(seed: int = 0, n_requests: int = 60):
    fleet = build_fleet(
        2,
        policy="least-loaded",
        trace_window=16,
        trace_period=32,
        admission=AdmissionController(SLOModel(max_delay_steps=16.0)),
        autotier=dict(near_frac=0.30, epoch_steps=4),
        elastic=dict(
            min_replicas=2, max_replicas=5, cooldown=3.0,
            up_shed_rate=0.05, up_backlog_frac=0.6, down_backlog_frac=0.15,
        ),
        seed=seed,
    )
    prof = dataclasses.replace(_profile(), prefix_share=0.9)
    gen = RequestGenerator(prof, vocab_size=fleet_vocab(), seed=seed)
    stats = fleet.run(gen, n_requests=n_requests, max_steps=800, submit_per_step=6)
    val = validate_fleet(fleet.export_profiles())
    return stats, val


def main():
    rows, tputs = [], {}
    for label, speeds in SPEEDS.items():
        for lockstep in (True, False):
            mode = "lockstep" if lockstep else "event"
            tput, stats = run_straggler_cell(speeds, lockstep)
            tputs[(label, mode)] = tput
            rows.append(
                (
                    label,
                    mode,
                    f"{tput:.2f}",
                    stats["tokens_decoded"],
                    f"{stats['virtual_time']:.0f}",
                    stats["requests_finished"],
                )
            )
    print("straggler study: decode throughput (tokens / virtual time), fixed horizon")
    print(fmt_table(rows, ("speeds", "mode", "tput", "tokens", "vtime", "finished")))

    gain = tputs[("4x-straggler", "event")] / max(tputs[("4x-straggler", "lockstep")], 1e-9)
    tie = tputs[("homogeneous", "event")] / max(tputs[("homogeneous", "lockstep")], 1e-9)
    print(f"\nevent-driven vs lockstep under a 4x straggler: {gain:.2f}x")
    print(f"homogeneous control (must tie, equivalence guarantee): {tie:.3f}x")

    stats, val = run_autoscale()
    ups = [e for e in stats["scale_events"] if e[1] == "up"]
    retires = [e for e in stats["scale_events"] if e[1] == "retire"]
    print(
        f"\nautoscale: burst of 6 req/tick on 2 replicas -> "
        f"{len(ups)} scale-up(s), {len(retires)} retire(s); "
        f"{stats['requests_finished']} finished, {stats['shed']} shed"
    )
    for vtime, action, rid in stats["scale_events"]:
        print(f"  t={vtime:5.1f}  {action:>6}  host {rid}")
    print(
        f"  fleet trace across the scale cycle (incl. retired hosts): "
        f"hit-ratio err {val['hit_ratio_error']*100:.2f}%, "
        f"R:W err {val['rw_ratio_error_pct']:+.2f}% ({val['trace_len']} accesses)"
    )

    ok = (
        gain > 1.5
        and abs(tie - 1.0) < 1e-9
        and ups
        and retires
        and val["hit_ratio_error"] <= 0.05
        and abs(val["rw_ratio_error_pct"]) <= 5.0
    )
    if not ok:
        print("straggler_bench: FAIL")
        return 1
    print("straggler_bench ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
